//! Padded static-shape encodings consumed by the AOT kernels.
//!
//! * [`EllBuffers`] — padded ELL: `colind/val/mask: [n_pad, w]` (pads are
//!   col=0, val=0, mask=0, so SpMM needs no mask and SDDMM/softmax use it).
//! * [`CooBuffers`] — padded COO for the vendor scatter baseline.
//! * [`HubSplit`] — the CTA-per-hub analog: light rows in a narrow ELL,
//!   hub rows (degree > `hub_t`) in a dedicated `[h_pad, w_hub]` block.
//!
//! Padding waste recorded here feeds the roofline estimate: it is the
//! TPU-bucketing analog of CUDA warp load imbalance.

use super::csr::Csr;

/// Padded ELL encoding of a CSR matrix at bucket shape `(n_pad, w)`.
#[derive(Debug, Clone, PartialEq)]
pub struct EllBuffers {
    pub n_rows: usize, // real rows (<= n_pad)
    pub n_pad: usize,
    pub w: usize,
    pub colind: Vec<i32>, // [n_pad * w], row-major
    pub val: Vec<f32>,
    pub mask: Vec<f32>,
}

impl EllBuffers {
    /// Pad `g` to bucket `(n_pad, w)`. Fails if the graph does not fit.
    pub fn from_csr(g: &Csr, n_pad: usize, w: usize) -> Result<EllBuffers, String> {
        if g.n_rows > n_pad {
            return Err(format!("{} rows > bucket n_pad {}", g.n_rows, n_pad));
        }
        let max_deg = g.max_degree();
        if max_deg > w {
            return Err(format!("max degree {max_deg} > bucket width {w}"));
        }
        if g.n_cols > n_pad {
            return Err(format!("{} cols > bucket n_pad {}", g.n_cols, n_pad));
        }
        let mut colind = vec![0i32; n_pad * w];
        let mut val = vec![0f32; n_pad * w];
        let mut mask = vec![0f32; n_pad * w];
        for i in 0..g.n_rows {
            let (cols, vals) = g.row(i);
            for (s, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                colind[i * w + s] = c as i32;
                val[i * w + s] = v;
                mask[i * w + s] = 1.0;
            }
        }
        Ok(EllBuffers { n_rows: g.n_rows, n_pad, w, colind, val, mask })
    }

    pub fn nnz(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Fraction of slots that are padding (cost-model feature).
    pub fn pad_waste(&self) -> f64 {
        let slots = (self.n_pad * self.w) as f64;
        if slots == 0.0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / slots
    }

    /// Round-trip back to CSR (drops padding). Test/verification aid.
    pub fn to_csr(&self, n_cols: usize) -> Csr {
        let rows = (0..self.n_rows)
            .map(|i| {
                (0..self.w)
                    .filter(|s| self.mask[i * self.w + s] > 0.0)
                    .map(|s| (self.colind[i * self.w + s] as u32,
                              self.val[i * self.w + s]))
                    .collect()
            })
            .collect();
        Csr::from_rows(n_cols, rows)
    }
}

/// Padded COO (row-major slot order — matches the ELL compaction the
/// baseline attention artifact performs; see `model.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct CooBuffers {
    pub nnz: usize, // real entries (<= nnz_pad)
    pub nnz_pad: usize,
    pub row: Vec<i32>,
    pub col: Vec<i32>,
    pub val: Vec<f32>,
}

impl CooBuffers {
    pub fn from_csr(g: &Csr, nnz_pad: usize) -> Result<CooBuffers, String> {
        if g.nnz() > nnz_pad {
            return Err(format!("nnz {} > bucket nnz_pad {}", g.nnz(), nnz_pad));
        }
        let mut row = Vec::with_capacity(nnz_pad);
        let mut col = Vec::with_capacity(nnz_pad);
        let mut val = Vec::with_capacity(nnz_pad);
        for i in 0..g.n_rows {
            let (cols, vals) = g.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                row.push(i as i32);
                col.push(c as i32);
                val.push(v);
            }
        }
        row.resize(nnz_pad, 0);
        col.resize(nnz_pad, 0);
        val.resize(nnz_pad, 0.0);
        Ok(CooBuffers { nnz: g.nnz(), nnz_pad, row, col, val })
    }
}

/// Hub partition of a CSR graph (paper §4.1 "hub-split").
#[derive(Debug, Clone, PartialEq)]
pub struct HubSplit {
    pub hub_t: usize,        // degree threshold used
    pub light: EllBuffers,   // hub rows zeroed out here
    pub hub_rows: Vec<i32>,  // [h_pad], padded with 0
    pub hub_colind: Vec<i32>, // [h_pad * w_hub]
    pub hub_val: Vec<f32>,
    pub n_hubs: usize,
}

impl HubSplit {
    /// Split at degree threshold `hub_t` into bucket shapes
    /// `(n_pad, w_light)` for light rows and `(h_pad, w_hub)` for hubs.
    pub fn from_csr(
        g: &Csr,
        hub_t: usize,
        n_pad: usize,
        w_light: usize,
        h_pad: usize,
        w_hub: usize,
    ) -> Result<HubSplit, String> {
        if g.n_rows > n_pad {
            return Err(format!("{} rows > n_pad {}", g.n_rows, n_pad));
        }
        let degs = g.degrees();
        let hubs: Vec<usize> =
            (0..g.n_rows).filter(|&i| degs[i] > hub_t).collect();
        if hubs.len() > h_pad {
            return Err(format!("{} hubs > bucket h_pad {}", hubs.len(), h_pad));
        }
        if let Some(&d) = hubs.iter().map(|&i| &degs[i]).max() {
            if d > w_hub {
                return Err(format!("hub degree {d} > bucket w_hub {w_hub}"));
            }
        }
        if let Some(d) = (0..g.n_rows)
            .filter(|&i| degs[i] <= hub_t)
            .map(|i| degs[i])
            .max()
        {
            if d > w_light {
                return Err(format!("light degree {d} > w_light {w_light}"));
            }
        }

        // Light ELL with hub rows zeroed.
        let mut colind = vec![0i32; n_pad * w_light];
        let mut val = vec![0f32; n_pad * w_light];
        let mut mask = vec![0f32; n_pad * w_light];
        for i in 0..g.n_rows {
            if degs[i] > hub_t {
                continue;
            }
            let (cols, vals) = g.row(i);
            for (s, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                colind[i * w_light + s] = c as i32;
                val[i * w_light + s] = v;
                mask[i * w_light + s] = 1.0;
            }
        }
        let light = EllBuffers {
            n_rows: g.n_rows,
            n_pad,
            w: w_light,
            colind,
            val,
            mask,
        };

        // Hub block: one padded neighbor list per hub row.
        let mut hub_rows = vec![0i32; h_pad];
        let mut hub_colind = vec![0i32; h_pad * w_hub];
        let mut hub_val = vec![0f32; h_pad * w_hub];
        for (k, &i) in hubs.iter().enumerate() {
            hub_rows[k] = i as i32;
            let (cols, vals) = g.row(i);
            for (s, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                hub_colind[k * w_hub + s] = c as i32;
                hub_val[k * w_hub + s] = v;
            }
        }
        Ok(HubSplit {
            hub_t,
            light,
            hub_rows,
            hub_colind,
            hub_val,
            n_hubs: hubs.len(),
        })
    }

    /// Heavy-row fraction — the paper sweeps split thresholds against
    /// "measured heavy-row fractions" (§8 Ablations).
    pub fn hub_fraction(&self) -> f64 {
        if self.light.n_rows == 0 {
            return 0.0;
        }
        self.n_hubs as f64 / self.light.n_rows as f64
    }
}

/// Default hub threshold: p99 degree, clamped to at least the mean
/// (used when `AUTOSAGE_HUB_T` = 0 = auto).
pub fn auto_hub_threshold(g: &Csr) -> usize {
    let p99 = g.degree_quantile(0.99);
    let mean = g.avg_degree();
    p99.max(mean).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, max_deg: usize) -> Csr {
        let mut rng = Rng::new(seed);
        let rows = (0..n)
            .map(|_| {
                let d = rng.below(max_deg + 1);
                let cols = rng.sample_distinct(n, d);
                cols.into_iter()
                    .map(|c| (c as u32, rng.next_f32()))
                    .collect()
            })
            .collect();
        Csr::from_rows(n, rows)
    }

    #[test]
    fn ell_roundtrip() {
        let g = random_graph(1, 50, 6);
        let e = EllBuffers::from_csr(&g, 64, 8).unwrap();
        assert_eq!(e.nnz(), g.nnz());
        let back = e.to_csr(g.n_cols);
        assert_eq!(back, g);
    }

    #[test]
    fn ell_rejects_too_small_bucket() {
        let g = random_graph(2, 50, 6);
        assert!(EllBuffers::from_csr(&g, 32, 8).is_err()); // rows don't fit
        let g2 = Csr::from_rows(4, vec![vec![(0, 1.0), (1, 1.0), (2, 1.0)]]);
        assert!(EllBuffers::from_csr(&g2, 8, 2).is_err()); // width too small
    }

    #[test]
    fn ell_pad_waste() {
        let g = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        let e = EllBuffers::from_csr(&g, 4, 2).unwrap();
        // 2 real slots of 8 -> 75% waste
        assert!((e.pad_waste() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn coo_layout_row_major() {
        let g = Csr::from_rows(
            3,
            vec![vec![(2, 1.0), (0, 2.0)], vec![], vec![(1, 3.0)]],
        );
        let c = CooBuffers::from_csr(&g, 5).unwrap();
        assert_eq!(c.nnz, 3);
        assert_eq!(&c.row[..3], &[0, 0, 2]);
        assert_eq!(&c.col[..3], &[0, 2, 1]); // row 0 sorted by col
        assert_eq!(&c.val[3..], &[0.0, 0.0]);
    }

    #[test]
    fn coo_rejects_overflow() {
        let g = random_graph(3, 20, 5);
        assert!(CooBuffers::from_csr(&g, g.nnz() - 1).is_err());
    }

    #[test]
    fn hub_split_partitions_exactly() {
        let mut rows: Vec<Vec<(u32, f32)>> = (0..32)
            .map(|i| vec![((i as u32 + 1) % 32, 1.0)])
            .collect();
        rows[3] = (0..20).map(|c| (c as u32, 1.0)).collect(); // hub deg 20
        rows[17] = (0..15).map(|c| (c as u32, 1.0)).collect(); // hub deg 15
        let g = Csr::from_rows(32, rows);
        let hs = HubSplit::from_csr(&g, 4, 32, 4, 8, 32).unwrap();
        assert_eq!(hs.n_hubs, 2);
        assert_eq!(&hs.hub_rows[..2], &[3, 17]);
        assert!((hs.hub_fraction() - 2.0 / 32.0).abs() < 1e-12);
        // Hub rows zeroed in light part.
        for s in 0..4 {
            assert_eq!(hs.light.mask[3 * 4 + s], 0.0);
            assert_eq!(hs.light.val[17 * 4 + s], 0.0);
        }
        // Light rows intact.
        assert_eq!(hs.light.mask[0], 1.0);
    }

    #[test]
    fn hub_split_mass_conserved() {
        // sum of light.val + hub_val == sum of g.val
        let g = random_graph(5, 64, 10);
        let t = 5;
        let hs = HubSplit::from_csr(&g, t, 64, t, 64, 16).unwrap();
        let total: f32 = g.val.iter().sum();
        let split: f32 =
            hs.light.val.iter().sum::<f32>() + hs.hub_val.iter().sum::<f32>();
        assert!((total - split).abs() < 1e-3);
    }

    #[test]
    fn hub_split_rejects_small_buckets() {
        let g = random_graph(7, 64, 10);
        assert!(HubSplit::from_csr(&g, 5, 64, 5, 0, 16).is_err() ||
                g.degrees().iter().all(|&d| d <= 5));
    }

    #[test]
    fn auto_threshold_sane() {
        let g = random_graph(9, 100, 8);
        let t = auto_hub_threshold(&g);
        assert!(t >= g.avg_degree() as usize);
        assert!(t <= g.max_degree().max(1));
    }
}
