//! Input feature extraction (paper §4.2: "#rows/nnz, degree quantiles,
//! F, device caps"). These drive the roofline shortlist; the cache key
//! uses the graph signature, not these floats.

use crate::graph::csr::METRIC_TILE_ROWS;
use crate::graph::Csr;
use crate::util::stats;

/// Canonical order of the numeric feature vector ([`InputFeatures::to_vec`]).
/// The trained cost model (`model/`) indexes features by position, so this
/// order is part of the model-file contract: changing it invalidates
/// persisted models (their stored `feature_names` will no longer match).
pub const FEATURE_NAMES: [&str; 13] = [
    "n_rows", "nnz", "f", "avg_deg", "p50_deg", "p90_deg", "p99_deg",
    "max_deg", "gini", "cv", "vec_aligned", "tile_fill", "band_frac",
];

/// Features of one (graph, F) scheduling input.
#[derive(Debug, Clone, PartialEq)]
pub struct InputFeatures {
    pub n_rows: usize,
    pub nnz: usize,
    pub f: usize,
    pub avg_deg: f64,
    pub p50_deg: f64,
    pub p90_deg: f64,
    pub p99_deg: f64,
    pub max_deg: usize,
    /// Degree Gini coefficient — skew (0 balanced → 1 hub-dominated).
    pub gini: f64,
    /// Degree coefficient of variation — secondary skew measure.
    pub cv: f64,
    /// Wide-lane ("vec") alignment: F % 128 == 0 (paper: F % 4 == 0).
    pub vec_aligned: bool,
    /// Per-tile (r=8) ELL fill ratio — row-LAYOUT-sensitive, unlike the
    /// degree stats above: `data::reorder` passes raise it, and cached
    /// schedules key on the reordered layout through the signature.
    pub tile_fill: f64,
    /// Normalized mean |row - col| edge distance (layout bandwidth).
    pub band_frac: f64,
}

impl InputFeatures {
    pub fn extract(g: &Csr, f: usize) -> InputFeatures {
        let degs: Vec<f64> = g.degrees().iter().map(|&d| d as f64).collect();
        let q = |p: f64| {
            if degs.is_empty() {
                0.0
            } else {
                stats::quantile(&degs, p)
            }
        };
        InputFeatures {
            n_rows: g.n_rows,
            nnz: g.nnz(),
            f,
            avg_deg: g.avg_degree(),
            p50_deg: q(0.5),
            p90_deg: q(0.9),
            p99_deg: q(0.99),
            max_deg: g.max_degree(),
            gini: stats::gini(&degs),
            cv: stats::cv(&degs),
            vec_aligned: f % 128 == 0,
            tile_fill: g.tile_fill(METRIC_TILE_ROWS),
            band_frac: g.bandwidth_frac(),
        }
    }

    /// The numeric feature vector in [`FEATURE_NAMES`] order (booleans
    /// as 0/1). This is what flows into the audit stream, the schedule
    /// cache, and ultimately the trained cost model.
    pub fn to_vec(&self) -> Vec<f64> {
        vec![
            self.n_rows as f64,
            self.nnz as f64,
            self.f as f64,
            self.avg_deg,
            self.p50_deg,
            self.p90_deg,
            self.p99_deg,
            self.max_deg as f64,
            self.gini,
            self.cv,
            if self.vec_aligned { 1.0 } else { 0.0 },
            self.tile_fill,
            self.band_frac,
        ]
    }

    /// Heavy-row fraction above a threshold (split-threshold ablation).
    pub fn heavy_fraction(g: &Csr, threshold: usize) -> f64 {
        if g.n_rows == 0 {
            return 0.0;
        }
        g.degrees().iter().filter(|&&d| d > threshold).count() as f64
            / g.n_rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, hub_skew};

    #[test]
    fn er_features_balanced() {
        let g = erdos_renyi(2000, 4.0, 32, 3);
        let f = InputFeatures::extract(&g, 64);
        assert_eq!(f.n_rows, 2000);
        assert!((f.avg_deg - 4.0).abs() < 0.4);
        assert!(f.gini < 0.4, "ER gini {}", f.gini);
        assert!(!f.vec_aligned);
    }

    #[test]
    fn hub_features_skewed() {
        let g = hub_skew(2000, 4, 0.15, 256, 3);
        let f = InputFeatures::extract(&g, 128);
        assert!(f.gini > 0.5, "hub gini {}", f.gini);
        assert_eq!(f.max_deg, 256);
        assert!(f.vec_aligned);
        assert!(f.p99_deg >= 250.0);
    }

    #[test]
    fn heavy_fraction_matches_construction() {
        let g = hub_skew(1000, 4, 0.15, 64, 3);
        let hf = InputFeatures::heavy_fraction(&g, 32);
        assert!((hf - 0.15).abs() < 0.01);
    }

    #[test]
    fn to_vec_matches_feature_names_order() {
        let g = erdos_renyi(256, 4.0, 32, 3);
        let f = InputFeatures::extract(&g, 128);
        let v = f.to_vec();
        assert_eq!(v.len(), FEATURE_NAMES.len());
        assert_eq!(v[0], f.n_rows as f64);
        assert_eq!(v[1], f.nnz as f64);
        assert_eq!(v[2], 128.0);
        assert_eq!(v[7], f.max_deg as f64);
        assert_eq!(v[10], 1.0, "F=128 is vec-aligned");
        assert_eq!(v[11], f.tile_fill);
        assert_eq!(v[12], f.band_frac);
        let g = erdos_renyi(256, 4.0, 32, 4);
        assert_eq!(InputFeatures::extract(&g, 64).to_vec()[10], 0.0);
    }

    #[test]
    fn degenerate_inputs_extract_without_panicking() {
        // 0-nnz and single-row graphs must produce finite features; the
        // scheduler still rejects them (typed EstimateError) before any
        // model prediction, but extraction itself cannot NaN.
        let empty = Csr::from_rows(2, vec![vec![], vec![]]);
        let f = InputFeatures::extract(&empty, 64);
        assert_eq!((f.n_rows, f.nnz, f.max_deg), (2, 0, 0));
        assert!(f.to_vec().iter().all(|v| v.is_finite()), "{:?}", f.to_vec());
        let single = Csr::from_rows(1, vec![vec![(0, 1.0)]]);
        let f = InputFeatures::extract(&single, 0);
        assert_eq!((f.n_rows, f.nnz), (1, 1));
        assert!(f.to_vec().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn features_stable_across_asg_roundtrip_and_unpermutation() {
        use crate::data::reorder::{permute_rows, reorder, ReorderPass};
        use crate::data::{read_asg, write_asg};
        let g = hub_skew(512, 3, 0.1, 32, 3);
        let dir = std::env::temp_dir().join("autosage_feature_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.asg");
        // .asg round-trip is lossless, so features are bit-identical.
        write_asg(&path, &g, None).unwrap();
        let back = read_asg(&path).unwrap();
        assert_eq!(
            InputFeatures::extract(&g, 64),
            InputFeatures::extract(&back.csr, 64)
        );
        // Reorder + un-permute restores the original layout, and with it
        // the layout-sensitive features (tile_fill / band_frac).
        let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
        let inv: Vec<usize> = r.inverse().into_iter().map(|v| v as usize).collect();
        let restored = permute_rows(&r.graph, &inv);
        assert_eq!(
            InputFeatures::extract(&g, 64),
            InputFeatures::extract(&restored, 64)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn layout_features_move_under_reorder_degree_features_dont() {
        use crate::data::reorder::{reorder, ReorderPass};
        let g = hub_skew(512, 3, 0.1, 32, 3);
        let r = reorder(&g, &[ReorderPass::SegmentSort]);
        let a = InputFeatures::extract(&g, 64);
        let b = InputFeatures::extract(&r.graph, 64);
        // Degree statistics are permutation-invariant…
        assert_eq!(a.nnz, b.nnz);
        assert_eq!(a.max_deg, b.max_deg);
        assert!((a.gini - b.gini).abs() < 1e-12);
        // …the layout features are not.
        assert!(
            b.tile_fill > a.tile_fill,
            "tile fill {} -> {}",
            a.tile_fill,
            b.tile_fill
        );
        assert!((0.0..=1.0).contains(&a.band_frac));
    }
}
