//! The AutoSAGE scheduler (paper §4.2): features → roofline estimate →
//! micro-probe → guardrail, with a persistent per-(device, graph, F, op)
//! decision cache and replay-only mode.

pub mod cache;
pub mod estimate;
pub mod features;
pub mod guardrail;
pub mod probe;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::config::Config;
use crate::graph::signature::graph_signature;
use crate::graph::Csr;
use crate::runtime::manifest::{ArtifactEntry, Manifest};

pub use cache::{cache_key, CacheSalvage, CachedChoice, ScheduleCache};
pub use estimate::{DeviceModel, EstimateError};
pub use features::InputFeatures;
pub use guardrail::Choice;
pub use probe::ProbeReport;

/// The scheduled operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    Spmm,
    Sddmm,
    Softmax,
    Attention,
}

impl Op {
    pub fn as_str(&self) -> &'static str {
        match self {
            Op::Spmm => "spmm",
            Op::Sddmm => "sddmm",
            Op::Softmax => "softmax",
            Op::Attention => "attention",
        }
    }

    pub fn parse(s: &str) -> Option<Op> {
        match s {
            "spmm" => Some(Op::Spmm),
            "sddmm" => Some(Op::Sddmm),
            "softmax" => Some(Op::Softmax),
            "attention" => Some(Op::Attention),
            _ => None,
        }
    }

    /// The vendor-baseline variant id for this op.
    pub fn baseline_variant(&self) -> &'static str {
        match self {
            Op::Spmm => "baseline_scatter",
            Op::Sddmm => "baseline_gather",
            Op::Softmax | Op::Attention => "baseline",
        }
    }

    /// Dense operand names the op consumes (probe input synthesis).
    pub fn dense_operands(&self) -> &'static [&'static str] {
        match self {
            Op::Spmm => &["b"],
            Op::Sddmm => &["x", "y"],
            Op::Softmax => &[],
            Op::Attention => &["q", "k", "v"],
        }
    }

    /// Whether this op's artifacts carry an `f` parameter.
    pub fn has_f(&self) -> bool {
        !matches!(self, Op::Softmax)
    }
}

/// Where a decision came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionSource {
    /// Persistent-cache hit (steady-state replay).
    Cache,
    /// Fresh probe run.
    Probe,
    /// Trained cost-model prediction, confident enough to skip probing.
    Model,
    /// Replay-only mode, no cache entry → forced baseline.
    ReplayFallback,
}

/// The outcome of `autosage_decide` for one (graph, F, op).
#[derive(Debug, Clone)]
pub struct Decision {
    pub op: Op,
    pub f: usize,
    pub key: String,
    pub choice: Choice,
    pub source: DecisionSource,
    /// Probed medians (0.0 on cache/replay paths for t_star when absent).
    pub t_baseline_ms: f64,
    pub t_star_ms: f64,
    /// Probe wall-clock overhead (0 for cache hits).
    pub probe_wall_ms: f64,
    /// `InputFeatures::to_vec()` of the decided input, carried on the
    /// PROBE path only: probe resolutions are training data, while
    /// model-predicted decisions deliberately carry none so the trainer
    /// never mines the model's own output as ground truth.
    pub features: Option<Vec<f64>>,
}

impl Decision {
    /// Paper tables' "choice" column: "autosage" or "baseline".
    pub fn choice_label(&self) -> &'static str {
        if self.choice.is_baseline() {
            "baseline"
        } else {
            "autosage"
        }
    }
}

/// Padded-slot count of a bucket — the tie-breaker for choosing among
/// fitting buckets of one variant (less padding = less work). Must be
/// used consistently by probe-entry selection AND deployment selection,
/// or the guardrail compares a different bucket than it deploys.
pub fn bucket_cost(entry: &ArtifactEntry) -> usize {
    let n_pad = entry.param_usize("n_pad").unwrap_or(usize::MAX / 4);
    if let Some(nnz_pad) = entry.param_usize("nnz_pad") {
        return nnz_pad + n_pad;
    }
    if let (Some(w_l), Some(h_pad), Some(w_h)) = (
        entry.param_usize("w_light"),
        entry.param_usize("h_pad"),
        entry.param_usize("w_hub"),
    ) {
        return n_pad * w_l + h_pad * w_h;
    }
    n_pad * entry.param_usize("w").unwrap_or(1)
}

/// Does a full-size artifact bucket fit this graph?
pub fn entry_fits(entry: &ArtifactEntry, g: &Csr) -> bool {
    let Some(n_pad) = entry.param_usize("n_pad") else { return false };
    if g.n_rows > n_pad || g.n_cols > n_pad {
        return false;
    }
    let v = entry.variant.as_str();
    if v == "baseline_scatter" || entry.op == "attention" && v == "baseline" {
        if let Some(nnz_pad) = entry.param_usize("nnz_pad") {
            if g.nnz() > nnz_pad {
                return false;
            }
        } else {
            return false;
        }
    }
    if v.starts_with("hub_") {
        let (Some(w_light), Some(h_pad), Some(w_hub)) = (
            entry.param_usize("w_light"),
            entry.param_usize("h_pad"),
            entry.param_usize("w_hub"),
        ) else {
            return false;
        };
        let degs = g.degrees();
        let hubs = degs.iter().filter(|&&d| d > w_light).count();
        let max_hub = degs.iter().copied().max().unwrap_or(0);
        return hubs <= h_pad && max_hub <= w_hub;
    }
    // ELL-pattern entries (plain spmm/sddmm/softmax/fused attention,
    // and the ELL side of the gather baselines).
    if let Some(w) = entry.param_usize("w") {
        if entry.inputs.iter().any(|i| i.name == "colind" || i.name == "val") {
            return g.max_degree() <= w;
        }
    }
    true
}

/// The scheduler: config + device model + decision cache.
pub struct Scheduler {
    pub cfg: Config,
    pub dev_model: DeviceModel,
    pub cache: ScheduleCache,
    pub probe_seed: u64,
    /// Flight recorder; when set together with [`Self::trace_ctx`],
    /// `decide` emits estimate/probe/guardrail spans and cache events.
    pub tracer: Option<std::sync::Arc<crate::obs::trace::Recorder>>,
    /// (trace, parent span) the next `decide` call belongs to.
    pub trace_ctx: Option<(crate::obs::trace::TraceId, crate::obs::trace::SpanId)>,
    /// Unified metrics registry; when set, `decide` counts decision
    /// outcomes (source, variant, probes, guardrail fallbacks).
    pub metrics: Option<std::sync::Arc<crate::obs::metrics::MetricsRegistry>>,
    /// Trained cost model; when set, cold keys are predicted first and
    /// probed only below the `model_confidence` threshold. Shared
    /// read-only across serve shards.
    pub model: Option<std::sync::Arc<crate::model::CostModel>>,
}

impl Scheduler {
    pub fn new(cfg: Config) -> Result<Scheduler> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let cache = if cfg.cache_path.is_empty() {
            ScheduleCache::in_memory()
        } else {
            ScheduleCache::load(std::path::Path::new(&cfg.cache_path))?
        };
        let model = if cfg.model_path.is_empty() {
            None
        } else {
            Some(std::sync::Arc::new(crate::model::read_model(
                std::path::Path::new(&cfg.model_path),
            )?))
        };
        Ok(Scheduler {
            cfg,
            dev_model: DeviceModel::default(),
            cache,
            probe_seed: 0xA0705A6E,
            tracer: None,
            trace_ctx: None,
            metrics: None,
            model,
        })
    }

    /// Persist the schedule cache, downgrading I/O failure to a warning:
    /// the decision is sound and already live in memory; only warm-start
    /// across processes is lost.
    fn persist_cache(
        &mut self,
        tracer: &Option<std::sync::Arc<crate::obs::trace::Recorder>>,
        tctx: Option<(crate::obs::trace::TraceId, crate::obs::trace::SpanId)>,
    ) {
        if let Err(e) = self.cache.save() {
            if let Some(tr) = tracer {
                tr.warn(tctx.map(|(t, _)| t), "cache_persist", &format!("{e:#}"));
            }
            if let Some(m) = &self.metrics {
                m.inc("autosage_cache_persist_errors_total");
            }
            eprintln!("autosage: warning: schedule cache persist failed: {e:#}");
        }
    }

    /// Count one decision outcome in the registry (no-op when unset):
    /// `autosage_scheduler_decisions_total{source=...}` + the chosen
    /// variant's `autosage_scheduler_variant_total{variant=...}`.
    fn count_decision(&self, source: &str, variant: &str) {
        if let Some(m) = &self.metrics {
            m.inc(&format!(
                "autosage_scheduler_decisions_total{{source=\"{source}\"}}"
            ));
            m.inc(&format!(
                "autosage_scheduler_variant_total{{variant=\"{variant}\"}}"
            ));
        }
    }

    /// `autosage_decide` (paper §4.2 pseudocode): cache → shortlist →
    /// probe → guardrail → cache.
    pub fn decide(
        &mut self,
        dev: &dyn Backend,
        manifest: &Manifest,
        g: &Csr,
        op: Op,
        f: usize,
    ) -> Result<(Decision, Option<ProbeReport>)> {
        let key = cache_key(
            &dev.signature(),
            &graph_signature(g),
            if op.has_f() { f } else { 0 },
            op.as_str(),
        );
        let tracer = self.tracer.clone();
        let tctx = self.trace_ctx;

        // 1. Cache hit → replay.
        if let Some(hit) = self.cache.get(&key) {
            if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
                tr.event(
                    trace,
                    Some(parent),
                    "cache_hit",
                    vec![
                        ("key".to_string(), key.clone()),
                        ("variant".to_string(), hit.variant.clone()),
                    ],
                );
            }
            let choice = if hit.variant == "baseline" {
                Choice::Baseline
            } else {
                Choice::Candidate(hit.variant.clone())
            };
            self.count_decision("cache", choice.variant());
            return Ok((
                Decision {
                    op,
                    f,
                    key,
                    choice,
                    source: DecisionSource::Cache,
                    t_baseline_ms: hit.t_baseline_ms,
                    t_star_ms: hit.t_star_ms,
                    probe_wall_ms: 0.0,
                    features: None,
                },
                None,
            ));
        }
        if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
            tr.event(
                trace,
                Some(parent),
                "cache_miss",
                vec![("key".to_string(), key.clone())],
            );
        }

        // 2. Replay-only mode: miss → guaranteed-safe baseline.
        if self.cfg.replay_only {
            self.count_decision("replay_fallback", "baseline");
            return Ok((
                Decision {
                    op,
                    f,
                    key,
                    choice: Choice::Baseline,
                    source: DecisionSource::ReplayFallback,
                    t_baseline_ms: 0.0,
                    t_star_ms: 0.0,
                    probe_wall_ms: 0.0,
                    features: None,
                },
                None,
            ));
        }

        // 3. Reject degenerate inputs with a typed error before any
        //    roofline math: 0 rows / 0 nnz / F=0 would otherwise surface
        //    as NaN scores or an unprobeable empty subgraph downstream.
        let estimate_start_us = tracer.as_ref().map(|tr| tr.now_us());
        let feats = InputFeatures::extract(g, f);
        estimate::validate_input(&feats, op.has_f(), &self.dev_model)?;
        let fq = if op.has_f() { Some(f) } else { None };
        let feats_vec = feats.to_vec();

        // 3.5 Learned scheduler: on a cold key, ask the trained cost
        //     model first. A confident prediction of a deployable
        //     variant skips the micro-probe entirely (the cold-start
        //     latency kill); a low-confidence one is remembered so the
        //     probe below can grade it (agree/disagree counters). A
        //     mispredicted variant is still oracle-safe — every variant
        //     computes the exact result, only the latency differs.
        let mut pending_prediction: Option<crate::model::Prediction> = None;
        if let Some(model) = self.model.clone() {
            let predict_start_us = tracer.as_ref().map(|tr| tr.now_us());
            if let Some(pred) = model.predict(op.as_str(), &feats_vec) {
                // Deployable = baseline, or a full-size artifact of the
                // predicted variant fits this graph under the same grid
                // gating the shortlist applies.
                let deployable = pred.variant == "baseline"
                    || manifest
                        .candidates(op.as_str(), fq, false)
                        .into_iter()
                        .any(|e| {
                            e.variant == pred.variant
                                && entry_fits(e, g)
                                && (self.cfg.allow_grid_kernels
                                    || dev.executes_grid_kernels()
                                    || e.param("r").is_none())
                        });
                let acted = deployable && pred.confidence >= self.cfg.model_confidence;
                if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
                    tr.span_between(
                        trace,
                        Some(parent),
                        "predict",
                        predict_start_us.unwrap_or(0),
                        tr.now_us(),
                        vec![
                            ("variant".to_string(), pred.variant.clone()),
                            (
                                "confidence".to_string(),
                                format!("{:.3}", pred.confidence),
                            ),
                            ("acted".to_string(), acted.to_string()),
                        ],
                    );
                }
                if acted {
                    let choice = if pred.variant == "baseline" {
                        Choice::Baseline
                    } else {
                        Choice::Candidate(pred.variant.clone())
                    };
                    if let Some(m) = &self.metrics {
                        m.inc("autosage_model_predictions_total");
                    }
                    // Predicted entries carry NO feature vector: the
                    // trainer must never see the model's own output as
                    // a probe-grade label (self-training feedback).
                    self.cache.insert(
                        key.clone(),
                        CachedChoice {
                            variant: choice.variant().to_string(),
                            t_baseline_ms: 0.0,
                            t_star_ms: 0.0,
                            alpha: self.cfg.alpha,
                            features: None,
                        },
                    );
                    self.persist_cache(&tracer, tctx);
                    self.count_decision("model", choice.variant());
                    return Ok((
                        Decision {
                            op,
                            f,
                            key,
                            choice,
                            source: DecisionSource::Model,
                            t_baseline_ms: 0.0,
                            t_star_ms: 0.0,
                            probe_wall_ms: 0.0,
                            features: None,
                        },
                        None,
                    ));
                }
                if let Some(m) = &self.metrics {
                    m.inc("autosage_model_low_confidence_probes_total");
                }
                pending_prediction = Some(pred);
            }
        }

        //    Shortlist by estimating the FULL-size candidates (their
        //    cost is what the decision commits to — grid kernels have
        //    per-step costs that grow with n_pad, so scoring the probe
        //    bucket would not extrapolate), then probe each winner's
        //    probe-size twin.
        // Small-enough inputs are probed on their full bucket — the
        // guardrail is then exact on the real input (Prop. 1); larger
        // ones probe an induced subgraph and scale by the estimate.
        let full_probe = g.n_rows <= self.cfg.probe_full_max_rows;
        let probe_entries = manifest.candidates(op.as_str(), fq, !full_probe);
        let sub = if full_probe {
            g.clone()
        } else {
            let probe_sub_rows = probe::probe_rows(g.n_rows, &self.cfg);
            g.probe_sample(probe_sub_rows, self.probe_seed)
        };
        let baseline = probe_entries
            .iter()
            .filter(|e| e.variant == op.baseline_variant() && entry_fits(e, &sub))
            .min_by_key(|e| bucket_cost(e))
            .copied()
            .ok_or_else(|| {
                anyhow!(
                    "no probe baseline artifact fits op={} f={f} (rows {})",
                    op.as_str(),
                    sub.n_rows
                )
            })?;
        let full_cands: Vec<&ArtifactEntry> = manifest
            .candidates(op.as_str(), fq, false)
            .into_iter()
            .filter(|e| e.variant != op.baseline_variant() && entry_fits(e, g))
            // Grid (row-tile) kernels join the executable candidate
            // space when the backend runs them at native cost (the
            // NativeBackend's tiled kernels) or when forced with
            // AUTOSAGE_GRID=1 (interpret-mode ablations; see config.rs).
            .filter(|e| {
                self.cfg.allow_grid_kernels
                    || dev.executes_grid_kernels()
                    || e.param("r").is_none()
            })
            .collect();
        let shortlisted = estimate::shortlist(
            &full_cands,
            &feats,
            &self.dev_model,
            self.cfg.allow_vec,
            self.cfg.top_k,
        );
        // Map each shortlisted full entry to its probe twin (same
        // variant; prefer the same preset bucket family), remembering
        // the estimate's full/probe cost ratio: probe timings are
        // *scaled by that ratio* before the guardrail, because grid
        // kernels have per-step costs that grow with n_pad and a raw
        // 512-row probe would not extrapolate ("estimate refined by
        // micro-probes", paper §1).
        let feats_probe = InputFeatures::extract(&sub, f);
        let mut short_refs: Vec<&ArtifactEntry> = Vec::new();
        let mut scale_of: std::collections::HashMap<String, f64> =
            std::collections::HashMap::new();
        let mut baseline_scale = 1.0;
        if full_probe {
            // Probe the shortlisted full-size entries themselves —
            // no twins, no scaling, Prop. 1 exact. One bucket per
            // variant: the shortlist is score-ascending, so the first
            // occurrence is the cheapest fitting bucket.
            for (full, _) in &shortlisted {
                if !short_refs
                    .iter()
                    .any(|e: &&ArtifactEntry| e.variant == full.variant)
                {
                    short_refs.push(*full);
                }
            }
        } else {
            for (full, est_full) in &shortlisted {
                let twin = probe_entries
                    .iter()
                    .filter(|p| p.variant == full.variant && entry_fits(p, &sub))
                    .min_by_key(|p| (p.preset_tag != full.preset_tag) as usize)
                    .copied();
                if let Some(t) = twin {
                    if !short_refs.iter().any(|e| e.name == t.name) {
                        let est_probe =
                            estimate::estimate_entry(t, &feats_probe, &self.dev_model);
                        let ratio = match est_probe {
                            Some(p) if p.score > 0.0 => {
                                (est_full.score / p.score).clamp(1e-3, 1e6)
                            }
                            _ => 1.0,
                        };
                        scale_of.insert(t.variant.clone(), ratio);
                        short_refs.push(t);
                    }
                }
            }
            // Baseline scale: full vs probe bucket of the vendor path.
            let bscale = manifest
                .candidates(op.as_str(), fq, false)
                .into_iter()
                .filter(|e| e.variant == op.baseline_variant() && entry_fits(e, g))
                .filter_map(|fe| {
                    let ef = estimate::estimate_entry(fe, &feats, &self.dev_model)?;
                    let ep = estimate::estimate_entry(
                        baseline,
                        &feats_probe,
                        &self.dev_model,
                    )?;
                    if ep.score > 0.0 {
                        Some((ef.score / ep.score).clamp(1e-3, 1e6))
                    } else {
                        None
                    }
                })
                .fold(f64::INFINITY, f64::min);
            if bscale.is_finite() {
                baseline_scale = bscale;
            }
        }

        if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
            tr.span_between(
                trace,
                Some(parent),
                "estimate",
                estimate_start_us.unwrap_or(0),
                tr.now_us(),
                vec![("shortlisted".to_string(), short_refs.len().to_string())],
            );
        }

        // 4. Micro-probe (on the subgraph built in step 3).
        let probe_start_us = tracer.as_ref().map(|tr| tr.now_us());
        let report = probe::run_probe(
            dev,
            op,
            f,
            &sub,
            baseline,
            &short_refs,
            &self.cfg,
            self.probe_seed,
        )?;
        if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
            tr.span_between(
                trace,
                Some(parent),
                "probe",
                probe_start_us.unwrap_or(0),
                tr.now_us(),
                vec![
                    ("probed".to_string(), report.candidates.len().to_string()),
                    ("wall_ms".to_string(), format!("{:.3}", report.wall_ms)),
                ],
            );
        }

        // 5. Guardrail on estimate-scaled probe timings (predicted
        //    full-graph medians).
        let probed: Vec<(String, f64)> = report
            .candidates
            .iter()
            .map(|r| {
                let s = scale_of.get(&r.variant).copied().unwrap_or(1.0);
                (r.variant.clone(), r.timing.median_ms * s)
            })
            .collect();
        let guardrail_start_us = tracer.as_ref().map(|tr| tr.now_us());
        let t_b = report.baseline.timing.median_ms * baseline_scale;
        let choice = guardrail::decide(&probed, t_b, self.cfg.alpha);
        if let Some(m) = &self.metrics {
            m.inc("autosage_scheduler_probes_total");
            // Guardrail fallback: candidates were probed but none beat
            // α·t_baseline, so the safe vendor path won.
            if choice.is_baseline() && !probed.is_empty() {
                m.inc("autosage_scheduler_guardrail_fallback_total");
            }
        }
        let t_star = probed
            .iter()
            .map(|(_, t)| *t)
            .fold(f64::INFINITY, f64::min);

        // Every probe outcome — winner, losers, and the vendor baseline
        // — becomes an audit row carrying this input's feature vector,
        // so the trainer learns from rejected variants and guardrail
        // fallbacks too, not only from executed decisions.
        if let Some(m) = &self.metrics {
            use crate::obs::metrics::{feature_bucket, AuditSample};
            let bucket = feature_bucket(g.n_rows, g.nnz(), f);
            for (variant, measured_ms) in &probed {
                let predicted_ms = shortlisted
                    .iter()
                    .find(|(e, _)| e.variant == *variant)
                    .map(|(_, est)| est.score * 1e3)
                    .unwrap_or(0.0);
                let outcome = if !choice.is_baseline() && choice.variant() == variant {
                    "chosen"
                } else {
                    "rejected"
                };
                let mut s = AuditSample::executed(
                    op.as_str(),
                    variant,
                    &bucket,
                    predicted_ms,
                    *measured_ms,
                );
                s.outcome = outcome.to_string();
                s.features = Some(feats_vec.clone());
                m.record_audit(s);
            }
            let base_predicted_ms = manifest
                .candidates(op.as_str(), fq, false)
                .into_iter()
                .filter(|e| e.variant == op.baseline_variant() && entry_fits(e, g))
                .filter_map(|e| estimate::estimate_entry(e, &feats, &self.dev_model))
                .map(|est| est.score * 1e3)
                .fold(f64::INFINITY, f64::min);
            let base_outcome = if choice.is_baseline() {
                // Won by default (nothing probed) vs guardrail fallback
                // (candidates probed, all rejected) — the fallback is
                // the negative label the trainer maps to "baseline".
                if probed.is_empty() {
                    "chosen"
                } else {
                    "fallback"
                }
            } else {
                "baseline"
            };
            let mut s = AuditSample::executed(
                op.as_str(),
                "baseline",
                &bucket,
                if base_predicted_ms.is_finite() {
                    base_predicted_ms
                } else {
                    0.0
                },
                t_b,
            );
            s.outcome = base_outcome.to_string();
            s.features = Some(feats_vec.clone());
            m.record_audit(s);

            // Low-confidence predictions were deferred to this probe:
            // grade them now that ground truth exists.
            if let Some(pred) = &pending_prediction {
                if pred.variant == choice.variant() {
                    m.inc("autosage_model_agree_total");
                } else {
                    m.inc("autosage_model_disagree_total");
                }
            }
        }
        if let (Some(tr), Some((trace, parent))) = (&tracer, tctx) {
            tr.span_between(
                trace,
                Some(parent),
                "guardrail",
                guardrail_start_us.unwrap_or(0),
                tr.now_us(),
                vec![
                    ("choice".to_string(), choice.variant().to_string()),
                    ("t_baseline_ms".to_string(), format!("{t_b:.3}")),
                    (
                        "t_star_ms".to_string(),
                        format!("{:.3}", if t_star.is_finite() { t_star } else { 0.0 }),
                    ),
                ],
            );
        }

        // 6. Cache + persist. Probe resolutions store the input's
        //    feature vector — they are the ground truth `autosage train`
        //    mines (model-predicted entries store none).
        self.cache.insert(
            key.clone(),
            CachedChoice {
                variant: choice.variant().to_string(),
                t_baseline_ms: t_b,
                t_star_ms: if t_star.is_finite() { t_star } else { 0.0 },
                alpha: self.cfg.alpha,
                features: Some(feats_vec.clone()),
            },
        );
        self.persist_cache(&tracer, tctx);

        self.count_decision("probe", choice.variant());
        Ok((
            Decision {
                op,
                f,
                key,
                choice,
                source: DecisionSource::Probe,
                t_baseline_ms: t_b,
                t_star_ms: if t_star.is_finite() { t_star } else { 0.0 },
                probe_wall_ms: report.wall_ms,
                features: Some(feats_vec),
            },
            Some(report),
        ))
    }

    /// Resolve the full-size artifact implementing `decision` on `g`.
    pub fn select_entry<'m>(
        &self,
        manifest: &'m Manifest,
        g: &Csr,
        op: Op,
        f: usize,
        variant: &str,
    ) -> Result<&'m ArtifactEntry> {
        let fq = if op.has_f() { Some(f) } else { None };
        let variant = if variant == "baseline" {
            op.baseline_variant()
        } else {
            variant
        };
        manifest
            .candidates(op.as_str(), fq, false)
            .into_iter()
            .filter(|e| e.variant == variant && entry_fits(e, g))
            // Smallest fitting bucket = least padding; same metric the
            // probe used, so the deployed entry is the probed entry.
            .min_by_key(|e| bucket_cost(e))
            .ok_or_else(|| {
                anyhow!(
                    "no full-size artifact for op={} f={f} variant={variant} \
                     fitting rows={} max_deg={} nnz={} — extend the catalog",
                    op.as_str(),
                    g.n_rows,
                    g.max_degree(),
                    g.nnz()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::Path;

    fn manifest_with_fits() -> Manifest {
        Manifest::parse(
            Path::new("/x"),
            r#"{"entries":[
          {"name":"full_ell","op":"spmm","variant":"ell_r8_f32",
           "params":{"n_pad":64,"w":8,"f":32,"r":8,"ft":32},
           "path":"a","inputs":[{"name":"colind","dtype":"s32","shape":[64,8]},
             {"name":"val","dtype":"f32","shape":[64,8]},
             {"name":"b","dtype":"f32","shape":[64,32]}]},
          {"name":"full_base","op":"spmm","variant":"baseline_scatter",
           "params":{"n_pad":64,"w":8,"f":32,"nnz_pad":128},
           "path":"a","inputs":[{"name":"row","dtype":"s32","shape":[128]},
             {"name":"col","dtype":"s32","shape":[128]},
             {"name":"val","dtype":"f32","shape":[128]},
             {"name":"b","dtype":"f32","shape":[64,32]}]},
          {"name":"full_hub","op":"spmm","variant":"hub_r8_f32",
           "params":{"n_pad":64,"w":8,"f":32,"r":8,"ft":32,
                     "w_light":2,"h_pad":4,"w_hub":8},
           "path":"a","inputs":[{"name":"hub_rows","dtype":"s32","shape":[4]}]}
        ]}"#,
        )
        .unwrap()
    }

    fn graph(max_deg: usize, n: usize) -> Csr {
        Csr::from_rows(
            n,
            (0..n)
                .map(|i| {
                    (0..max_deg.min(if i == 0 { max_deg } else { 1 }))
                        .map(|k| (((i + k + 1) % n) as u32, 1.0f32))
                        .collect()
                })
                .collect(),
        )
    }

    #[test]
    fn op_roundtrip() {
        for op in [Op::Spmm, Op::Sddmm, Op::Softmax, Op::Attention] {
            assert_eq!(Op::parse(op.as_str()), Some(op));
        }
        assert_eq!(Op::parse("nope"), None);
    }

    #[test]
    fn fits_ell_by_max_degree() {
        let m = manifest_with_fits();
        let e = m.by_name("full_ell").unwrap();
        assert!(entry_fits(e, &graph(8, 32)));
        assert!(!entry_fits(e, &graph(9, 32))); // row 0 degree 9 > w 8
        assert!(!entry_fits(e, &graph(2, 100))); // rows exceed n_pad
    }

    #[test]
    fn fits_scatter_by_nnz() {
        let m = manifest_with_fits();
        let e = m.by_name("full_base").unwrap();
        assert!(entry_fits(e, &graph(8, 32)));
        let big = Csr::from_rows(
            60,
            (0..60)
                .map(|i| (0..3).map(|k| (((i + k) % 60) as u32, 1.0f32)).collect())
                .collect(),
        );
        assert!(big.nnz() > 128);
        assert!(!entry_fits(e, &big));
    }

    #[test]
    fn fits_hub_by_hub_population() {
        let m = manifest_with_fits();
        let e = m.by_name("full_hub").unwrap();
        // 1 hub (row 0 deg 8 > w_light 2), others deg 1 -> fits
        assert!(entry_fits(e, &graph(8, 32)));
        // all rows deg 3 -> 32 hubs > h_pad 4 -> no fit
        let dense = Csr::from_rows(
            32,
            (0..32)
                .map(|i| (0..3).map(|k| (((i + k) % 32) as u32, 1.0f32)).collect())
                .collect(),
        );
        assert!(!entry_fits(e, &dense));
    }

    #[test]
    fn select_entry_prefers_smallest_fit_and_maps_baseline() {
        let cfg = Config { cache_path: String::new(), ..Config::default() };
        let s = Scheduler::new(cfg).unwrap();
        let m = manifest_with_fits();
        let g = graph(8, 32);
        let e = s.select_entry(&m, &g, Op::Spmm, 32, "baseline").unwrap();
        assert_eq!(e.variant, "baseline_scatter");
        let e = s.select_entry(&m, &g, Op::Spmm, 32, "ell_r8_f32").unwrap();
        assert_eq!(e.name, "full_ell");
        assert!(s.select_entry(&m, &g, Op::Spmm, 64, "ell_r8_f32").is_err());
    }
}
