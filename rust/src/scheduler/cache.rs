//! Persistent schedule cache with replay (paper §4.2 + §10).
//!
//! Key: `(device_sig, graph_sig, F, op)` — exactly the paper's tuple.
//! Values record the chosen variant plus the probe evidence (baseline
//! and candidate medians) so replayed runs can audit why a choice was
//! made. The file is pretty-printed JSON for diffability.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One cached decision.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedChoice {
    pub variant: String, // "baseline" or a candidate variant id
    pub t_baseline_ms: f64,
    pub t_star_ms: f64,
    pub alpha: f64,
    /// `InputFeatures::to_vec()` of the input this choice was probed on,
    /// mined by `autosage train` as a labeled example. `None` on entries
    /// written before this field existed — and deliberately `None` on
    /// model-predicted entries, so the trainer never feeds the model its
    /// own predictions back as ground truth.
    pub features: Option<Vec<f64>>,
}

/// The cache: an ordered map (stable file output) + optional backing file.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, CachedChoice>,
    /// Telemetry counters (§8.6 warm-up vs steady-state accounting).
    pub hits: usize,
    pub misses: usize,
    /// Individually-corrupt entries dropped by the last load (salvage
    /// recovery: one bad entry no longer poisons the whole file).
    pub quarantined: usize,
    /// Unsaved in-memory changes (entries *or* counters). Lets callers
    /// buffer writes and flush periodically instead of on every insert.
    dirty: bool,
}

/// What [`ScheduleCache::load_salvaged`] had to do to produce a usable
/// cache.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CacheSalvage {
    /// Individually-corrupt entries dropped (file kept).
    pub entries_quarantined: usize,
    /// The whole file was unreadable/unparseable and was moved aside to
    /// `<path>.corrupt`; the cache restarted empty.
    pub file_reset: bool,
}

/// Compose the paper's cache key.
pub fn cache_key(device_sig: &str, graph_sig: &str, f: usize, op: &str) -> String {
    format!("{device_sig}|{graph_sig}|F{f}|{op}")
}

/// Cache-file schema version. Bump when the JSON layout changes; load
/// rejects anything else rather than misinterpreting it.
pub const CACHE_VERSION: i64 = 1;

impl ScheduleCache {
    /// In-memory cache (tests, `AUTOSAGE_CACHE=""`).
    pub fn in_memory() -> ScheduleCache {
        ScheduleCache::default()
    }

    /// Load from `path`, creating an empty cache if the file is absent.
    pub fn load(path: &Path) -> Result<ScheduleCache> {
        let mut cache = ScheduleCache {
            path: Some(path.to_path_buf()),
            ..Default::default()
        };
        if path.exists() {
            let text = crate::util::iofault::read_to_string("scheduler.cache.read", path)
                .with_context(|| format!("reading cache {}", path.display()))?;
            let root = Json::parse(&text).map_err(|e| anyhow!("cache: {e}"))?;
            let version = root.get("version").as_i64().ok_or_else(|| {
                anyhow!("cache {}: missing version field", path.display())
            })?;
            if version != CACHE_VERSION {
                return Err(anyhow!(
                    "cache {}: unsupported version {version} (expected \
                     {CACHE_VERSION}); delete or regenerate the file",
                    path.display()
                ));
            }
            // Lifetime hit/miss counters persist across sessions (§8.6
            // warm-up vs steady-state accounting survives restarts).
            cache.hits = root.get("hits").as_usize().unwrap_or(0);
            cache.misses = root.get("misses").as_usize().unwrap_or(0);
            if let Some(obj) = root.get("entries").as_obj() {
                for (k, v) in obj {
                    let variant = v.get("variant").as_str().unwrap_or("");
                    let t_baseline_ms = v.get("t_baseline_ms").as_f64().unwrap_or(0.0);
                    let t_star_ms = v.get("t_star_ms").as_f64().unwrap_or(0.0);
                    // Salvage recovery: an individually-corrupt entry is
                    // quarantined (dropped + counted), it no longer
                    // poisons the whole file. Silently defaulting the
                    // variant to "baseline" would still be wrong — a
                    // corrupt entry must never replay as plausible.
                    if variant.is_empty()
                        || !t_baseline_ms.is_finite()
                        || !t_star_ms.is_finite()
                    {
                        cache.quarantined += 1;
                        continue;
                    }
                    cache.entries.insert(
                        k.clone(),
                        CachedChoice {
                            variant: variant.to_string(),
                            t_baseline_ms,
                            t_star_ms,
                            alpha: v.get("alpha").as_f64().unwrap_or(0.95),
                            features: v
                                .get("features")
                                .as_arr()
                                .map(|arr| arr.iter().filter_map(|x| x.as_f64()).collect()),
                        },
                    );
                }
            }
            if cache.quarantined > 0 {
                crate::util::iofault::recovery()
                    .cache_entries_quarantined
                    .fetch_add(cache.quarantined as u64, std::sync::atomic::Ordering::Relaxed);
                // The quarantined keys are gone from memory; persisting
                // the salvaged view drops them from disk too.
                cache.dirty = true;
            }
        }
        Ok(cache)
    }

    /// Salvage load that never fails on corruption: per-entry damage is
    /// quarantined by [`ScheduleCache::load`]; file-level damage
    /// (unparseable JSON, missing/unsupported version, unreadable
    /// bytes) moves the file aside to `<path>.corrupt` (preserving the
    /// evidence) and restarts with an empty cache. This is the load
    /// path for long-lived pools, where "refuse to start" is worse than
    /// "reprobe a cold cache".
    pub fn load_salvaged(path: &Path) -> (ScheduleCache, CacheSalvage) {
        match ScheduleCache::load(path) {
            Ok(cache) => {
                let report = CacheSalvage {
                    entries_quarantined: cache.quarantined,
                    file_reset: false,
                };
                (cache, report)
            }
            Err(_) => {
                let mut aside = path.as_os_str().to_os_string();
                aside.push(".corrupt");
                let _ = fs::rename(path, PathBuf::from(aside));
                crate::util::iofault::recovery()
                    .cache_files_reset
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let cache = ScheduleCache {
                    path: Some(path.to_path_buf()),
                    dirty: true,
                    ..Default::default()
                };
                (cache, CacheSalvage { entries_quarantined: 0, file_reset: true })
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&mut self, key: &str) -> Option<CachedChoice> {
        let hit = self.entries.get(key).cloned();
        if hit.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        // Counters are persisted state too: a warm-only run (all hits,
        // no inserts) must still flush so `cache stats` stays accurate.
        self.dirty = true;
        hit
    }

    /// Peek without touching hit/miss counters.
    pub fn peek(&self, key: &str) -> Option<&CachedChoice> {
        self.entries.get(key)
    }

    pub fn insert(&mut self, key: String, choice: CachedChoice) {
        self.entries.insert(key, choice);
        self.dirty = true;
    }

    /// Backing file path, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Whether in-memory state (entries or counters) differs from disk.
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    pub fn mark_dirty(&mut self) {
        self.dirty = true;
    }

    pub fn clear_dirty(&mut self) {
        self.dirty = false;
    }

    /// The cache file's JSON text (what `save` writes), for callers that
    /// want to serialize under a lock but do file I/O outside it.
    pub fn serialize(&self) -> String {
        let mut obj = BTreeMap::new();
        for (k, v) in &self.entries {
            let mut pairs = vec![
                ("variant", Json::str(v.variant.clone())),
                ("t_baseline_ms", Json::num(v.t_baseline_ms)),
                ("t_star_ms", Json::num(v.t_star_ms)),
                ("alpha", Json::num(v.alpha)),
            ];
            if let Some(fv) = &v.features {
                let arr = fv.iter().map(|&x| Json::num(x)).collect();
                pairs.push(("features", Json::Arr(arr)));
            }
            obj.insert(k.clone(), Json::obj(pairs));
        }
        let root = Json::obj(vec![
            ("version", Json::num(CACHE_VERSION as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("entries", Json::Obj(obj)),
        ]);
        root.pretty()
    }

    /// Persist to the backing file (no-op for in-memory caches). Clears
    /// the dirty flag on success.
    pub fn save(&mut self) -> Result<()> {
        let Some(path) = self.path.clone() else {
            self.dirty = false;
            return Ok(());
        };
        write_atomic(&path, &self.serialize())?;
        self.dirty = false;
        Ok(())
    }

    /// Persist only if there are unsaved changes.
    pub fn save_if_dirty(&mut self) -> Result<()> {
        if self.dirty {
            self.save()
        } else {
            Ok(())
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.dirty = true;
    }

    /// Dump entries for the CLI (`autosage cache dump`).
    pub fn dump(&self) -> Vec<(String, CachedChoice)> {
        self.entries
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

/// Crash-safe file write: a sibling temp file renamed over the target —
/// a crash mid-write leaves the old file intact instead of a
/// truncated/corrupt one. Shared by `ScheduleCache::save` and the serve
/// pool's off-mutex cache flush. Routed through the I/O fault injector
/// (site `scheduler.cache.write`), which also owns the bounded retry
/// that absorbs injected torn writes / ENOSPC / failed renames.
pub(crate) fn write_atomic(path: &Path, text: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir).ok();
    }
    crate::util::iofault::write_atomic("scheduler.cache.write", path, text.as_bytes())
        .with_context(|| format!("writing cache {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("autosage_cache_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> CachedChoice {
        CachedChoice {
            variant: "ell_r8_f32".into(),
            t_baseline_ms: 1.5,
            t_star_ms: 0.4,
            alpha: 0.95,
            features: None,
        }
    }

    #[test]
    fn key_format_matches_paper_tuple() {
        let k = cache_key("cpu-1", "abc123", 64, "spmm");
        assert_eq!(k, "cpu-1|abc123|F64|spmm");
    }

    #[test]
    fn roundtrip_through_file() {
        let path = tmpfile("roundtrip.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        assert!(c.is_empty());
        c.insert(cache_key("d", "g", 64, "spmm"), sample());
        c.save().unwrap();

        let mut c2 = ScheduleCache::load(&path).unwrap();
        let got = c2.get(&cache_key("d", "g", 64, "spmm")).unwrap();
        assert_eq!(got, sample());
        assert_eq!(c2.hits, 1);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn features_round_trip_and_stay_optional() {
        let path = tmpfile("features.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        let with = CachedChoice {
            features: Some(vec![100.0, 400.0, 64.0, 4.0]),
            ..sample()
        };
        c.insert("probed".into(), with.clone());
        c.insert("predicted".into(), sample());
        c.save().unwrap();
        let mut c2 = ScheduleCache::load(&path).unwrap();
        assert_eq!(c2.get("probed"), Some(with));
        assert_eq!(c2.get("predicted").unwrap().features, None);
        // Pre-features cache files (version 1, no features key) load.
        fs::write(
            &path,
            r#"{"version": 1, "entries": {"k": {"variant": "v", "alpha": 0.9}}}"#,
        )
        .unwrap();
        let mut c3 = ScheduleCache::load(&path).unwrap();
        assert_eq!(c3.get("k").unwrap().features, None);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn hit_miss_counters() {
        let mut c = ScheduleCache::in_memory();
        assert!(c.get("nope").is_none());
        c.insert("k".into(), sample());
        assert!(c.get("k").is_some());
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn different_device_is_different_key() {
        // Paper §12: cache schema encodes device/toolchain so a cache
        // from another machine is never reused.
        assert_ne!(
            cache_key("cpu-A", "g", 64, "spmm"),
            cache_key("cpu-B", "g", 64, "spmm")
        );
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_file() {
        let path = tmpfile("atomic.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        c.insert("k".into(), sample());
        c.save().unwrap();
        assert!(path.exists());
        assert!(
            !path.with_file_name("atomic.json.tmp").exists(),
            "temp file must be renamed away"
        );
        // Overwriting an existing cache stays parseable.
        c.insert("k2".into(), sample());
        c.save().unwrap();
        assert_eq!(ScheduleCache::load(&path).unwrap().len(), 2);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_missing_version() {
        let path = tmpfile("nover.json");
        fs::write(&path, r#"{"entries": {}}"#).unwrap();
        let err = ScheduleCache::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_unsupported_version() {
        let path = tmpfile("futver.json");
        fs::write(&path, r#"{"version": 99, "entries": {}}"#).unwrap();
        let err = ScheduleCache::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported version"), "{err:#}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn load_quarantines_corrupt_entries_and_keeps_good_ones() {
        for (name, body) in [
            (
                "novariant.json",
                r#"{"version": 1, "entries": {"bad": {"t_baseline_ms": 1.0}, "good": {"variant": "v", "t_baseline_ms": 1.0, "t_star_ms": 0.5, "alpha": 0.95}}}"#,
            ),
            (
                "emptyvariant.json",
                r#"{"version": 1, "entries": {"bad": {"variant": ""}, "good": {"variant": "v", "t_baseline_ms": 1.0, "t_star_ms": 0.5, "alpha": 0.95}}}"#,
            ),
        ] {
            let path = tmpfile(name);
            fs::write(&path, body).unwrap();
            let c = ScheduleCache::load(&path).unwrap();
            assert_eq!(c.quarantined, 1, "{name}");
            assert_eq!(c.len(), 1, "{name}: the good entry survives");
            assert!(c.peek("good").is_some(), "{name}");
            assert!(c.peek("bad").is_none(), "{name}");
            // A salvaged load is dirty: the next save drops the
            // quarantined entry from disk too.
            assert!(c.is_dirty(), "{name}");
            let _ = fs::remove_file(&path);
        }
    }

    #[test]
    fn load_salvaged_resets_unparseable_files_aside() {
        let path = tmpfile("salvage_reset.json");
        fs::write(&path, "{definitely not json").unwrap();
        let (c, report) = ScheduleCache::load_salvaged(&path);
        assert!(report.file_reset);
        assert_eq!(report.entries_quarantined, 0);
        assert!(c.is_empty());
        assert!(c.is_dirty());
        assert!(!path.exists(), "corrupt file moved aside");
        let mut aside = path.as_os_str().to_os_string();
        aside.push(".corrupt");
        let aside = PathBuf::from(aside);
        assert!(aside.exists(), "evidence preserved at .corrupt");
        let _ = fs::remove_file(&aside);
    }

    #[test]
    fn load_salvaged_is_a_passthrough_for_healthy_files() {
        let path = tmpfile("salvage_ok.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        c.insert("k".into(), sample());
        c.save().unwrap();
        let (c2, report) = ScheduleCache::load_salvaged(&path);
        assert_eq!(report, CacheSalvage::default());
        assert_eq!(c2.len(), 1);
        assert!(!c2.is_dirty());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn hit_miss_counters_persist_across_save_load() {
        let path = tmpfile("counters.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        c.insert("k".into(), sample());
        assert!(c.get("k").is_some());
        assert!(c.get("missing").is_none());
        c.save().unwrap();
        let c2 = ScheduleCache::load(&path).unwrap();
        assert_eq!((c2.hits, c2.misses), (1, 1));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupted_file_is_an_error() {
        let path = tmpfile("corrupt.json");
        fs::write(&path, "{not json").unwrap();
        assert!(ScheduleCache::load(&path).is_err());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = ScheduleCache::in_memory();
        c.insert("k".into(), sample());
        c.save().unwrap(); // must not panic or write anywhere
    }

    #[test]
    fn dirty_tracks_mutations_and_save() {
        let path = tmpfile("dirty.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        assert!(!c.is_dirty(), "fresh load is clean");
        c.insert("k".into(), sample());
        assert!(c.is_dirty());
        c.save().unwrap();
        assert!(!c.is_dirty(), "save clears dirty");
        // Counter bumps alone (warm-only run) also dirty the cache.
        assert!(c.get("k").is_some());
        assert!(c.is_dirty());
        c.save_if_dirty().unwrap();
        assert!(!c.is_dirty());
        let reloaded = ScheduleCache::load(&path).unwrap();
        assert_eq!(reloaded.hits, 1);
        // save_if_dirty on a clean cache must not rewrite the file.
        let mtime_before = fs::metadata(&path).unwrap().modified().unwrap();
        let mut c2 = ScheduleCache::load(&path).unwrap();
        c2.save_if_dirty().unwrap();
        assert_eq!(
            fs::metadata(&path).unwrap().modified().unwrap(),
            mtime_before
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn serialize_matches_save_output() {
        let path = tmpfile("serialize.json");
        let _ = fs::remove_file(&path);
        let mut c = ScheduleCache::load(&path).unwrap();
        c.insert("k".into(), sample());
        let text = c.serialize();
        c.save().unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), text);
        let _ = fs::remove_file(&path);
    }
}
