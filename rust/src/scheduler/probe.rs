//! On-device micro-probe (paper §4.2): time the shortlisted candidates
//! and the baseline on an induced subgraph (default 2–3% of rows,
//! min 512) for `n` iterations under a wall-time cap.
//!
//! Inputs are packed once per candidate and handed to the backend's
//! timing loop (`Backend::time_entry`), which uploads once and runs
//! execute + output sync per iteration — mirroring CUDA-event kernel
//! timing as closely as each engine allows.

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::config::Config;
use crate::graph::Csr;
use crate::ops::pack::{pack_inputs, OpData};
use crate::runtime::manifest::ArtifactEntry;
use crate::util::rng::Rng;
use crate::util::stats::TimingSummary;
use crate::util::timing::Stopwatch;

use super::Op;

/// Timing of one probed entry.
#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub entry_name: String,
    pub variant: String,
    pub timing: TimingSummary,
}

/// Full probe report for one decision.
#[derive(Debug, Clone)]
pub struct ProbeReport {
    pub probe_rows: usize,
    pub baseline: ProbeResult,
    pub candidates: Vec<ProbeResult>,
    /// Total wall time of the probe phase (overhead accounting, §8.6).
    pub wall_ms: f64,
}

/// Number of probe rows for a graph (paper default: 2% of rows, min 512).
pub fn probe_rows(n_rows: usize, cfg: &Config) -> usize {
    ((n_rows as f64 * cfg.probe_frac) as usize)
        .max(cfg.probe_min_rows)
        .min(n_rows)
}

/// Deterministic random dense operands for an op at the probe size.
/// Probe timings must not depend on operand values, but deterministic
/// inputs keep replays bit-identical.
pub fn synth_operands(op: Op, n_rows: usize, f: usize, seed: u64) -> OpData {
    let mut rng = Rng::new(seed);
    let mut data = OpData::new();
    for name in op.dense_operands() {
        let v: Vec<f32> = (0..n_rows * f).map(|_| rng.next_f32() - 0.5).collect();
        data = data.with(name, v);
    }
    data
}

/// Time one entry on `g` with operands `data`: pack once, then hand the
/// packed tensors to the backend's upload-once timed loop.
pub fn time_entry(
    dev: &dyn Backend,
    entry: &ArtifactEntry,
    g: &Csr,
    data: &OpData,
    warmup: usize,
    iters: usize,
    cap_ms: f64,
) -> Result<TimingSummary> {
    dev.load(entry)?;
    let inputs = pack_inputs(entry, g, data)?;
    dev.time_entry(entry, &inputs, warmup, iters, cap_ms)
}

/// Run the micro-probe: baseline + each shortlisted candidate on the
/// induced subgraph `sub` (built once by the caller, who also needs it
/// for bucket-fit checks — see `Scheduler::decide`).
#[allow(clippy::too_many_arguments)]
pub fn run_probe(
    dev: &dyn Backend,
    op: Op,
    f: usize,
    sub: &Csr,
    baseline: &ArtifactEntry,
    shortlisted: &[&ArtifactEntry],
    cfg: &Config,
    seed: u64,
) -> Result<ProbeReport> {
    let sw = Stopwatch::start();
    let rows = sub.n_rows;
    let data = synth_operands(op, sub.n_rows, f, seed ^ 0x5eed);

    let time = |e: &ArtifactEntry| -> Result<ProbeResult> {
        let timing = time_entry(dev, e, sub, &data, 1, cfg.probe_iters, cfg.probe_cap_ms)?;
        Ok(ProbeResult {
            entry_name: e.name.clone(),
            variant: e.variant.clone(),
            timing,
        })
    };

    let baseline_res = time(baseline)
        .map_err(|e| anyhow!("probing baseline {}: {e}", baseline.name))?;
    let mut candidates = Vec::with_capacity(shortlisted.len());
    for e in shortlisted {
        candidates.push(time(e).map_err(|er| anyhow!("probing {}: {er}", e.name))?);
    }
    Ok(ProbeReport {
        probe_rows: rows,
        baseline: baseline_res,
        candidates,
        wall_ms: sw.ms(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_rows_respects_min_and_frac() {
        let cfg = Config::default(); // frac 0.02, min 512
        assert_eq!(probe_rows(4096, &cfg), 512); // 2% = 82 -> min 512
        assert_eq!(probe_rows(100_000, &cfg), 2000);
        assert_eq!(probe_rows(300, &cfg), 300); // capped at graph size
    }

    #[test]
    fn synth_operands_deterministic_and_shaped() {
        let a = synth_operands(Op::Sddmm, 16, 8, 7);
        let b = synth_operands(Op::Sddmm, 16, 8, 7);
        assert_eq!(a.dense.get("x"), b.dense.get("x"));
        assert_eq!(a.dense.get("y").unwrap().len(), 128);
        assert!(a.dense.get("b").is_none());
        let c = synth_operands(Op::Spmm, 16, 8, 7);
        assert!(c.dense.contains_key("b"));
        let d = synth_operands(Op::Attention, 4, 4, 1);
        assert_eq!(d.dense.len(), 3);
    }
}
