//! The guardrail (paper §4.2 + Proposition 1): accept the best probed
//! candidate iff `t* <= α · t_b`, else fall back to the vendor baseline.
//! With α ≤ 1 the chosen runtime never exceeds the baseline's on the
//! probed input — the non-regression guarantee.

/// Outcome of a guardrail evaluation.
#[derive(Debug, Clone, PartialEq)]
pub enum Choice {
    /// Candidate accepted (variant id).
    Candidate(String),
    /// Fall back to the vendor baseline.
    Baseline,
}

impl Choice {
    pub fn variant(&self) -> &str {
        match self {
            Choice::Candidate(v) => v,
            Choice::Baseline => "baseline",
        }
    }
    pub fn is_baseline(&self) -> bool {
        matches!(self, Choice::Baseline)
    }
}

/// Apply the guardrail to probe results.
///
/// `candidates` are (variant, median_ms) pairs from the micro-probe;
/// `t_b_ms` the probed baseline. Exact pseudocode from the paper:
/// pick `t* = min`, accept iff `t* <= alpha * t_b`.
pub fn decide(candidates: &[(String, f64)], t_b_ms: f64, alpha: f64) -> Choice {
    assert!(alpha > 0.0, "alpha must be positive");
    let best = candidates
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    match best {
        Some((variant, t_star)) if *t_star <= alpha * t_b_ms => {
            Choice::Candidate(variant.clone())
        }
        _ => Choice::Baseline,
    }
}

/// The chosen runtime implied by a decision (Proposition 1 quantity):
/// candidate time if accepted, else the baseline time.
pub fn chosen_time(candidates: &[(String, f64)], t_b_ms: f64, alpha: f64) -> f64 {
    match decide(candidates, t_b_ms, alpha) {
        Choice::Baseline => t_b_ms,
        Choice::Candidate(v) => {
            candidates.iter().find(|(c, _)| *c == v).map(|(_, t)| *t).unwrap()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn c(v: &str, t: f64) -> (String, f64) {
        (v.to_string(), t)
    }

    #[test]
    fn accepts_clear_win() {
        let cands = [c("ell_r8_f32", 0.5), c("hub_r8_f32", 0.8)];
        assert_eq!(
            decide(&cands, 1.0, 0.95),
            Choice::Candidate("ell_r8_f32".into())
        );
    }

    #[test]
    fn rejects_marginal_win_below_alpha() {
        // 0.97 < 1.0 but > 0.95 * 1.0 -> fallback
        let cands = [c("ell_r8_f32", 0.97)];
        assert_eq!(decide(&cands, 1.0, 0.95), Choice::Baseline);
    }

    #[test]
    fn alpha_098_accepts_more_than_095() {
        // The paper's §8.3: larger alpha prefers candidates more often
        // (accepts smaller margins).
        let cands = [c("x", 0.97)];
        assert_eq!(decide(&cands, 1.0, 0.98).variant(), "x");
        assert!(decide(&cands, 1.0, 0.95).is_baseline());
    }

    #[test]
    fn empty_candidates_fall_back() {
        assert!(decide(&[], 1.0, 0.95).is_baseline());
    }

    #[test]
    fn proposition_1_non_regression_property() {
        // For any randomized probe outcome and any alpha <= 1,
        // chosen_time <= t_b. (Property test over 10k random cases.)
        let mut rng = Rng::new(2025);
        for _ in 0..10_000 {
            let t_b = rng.next_f64() * 10.0 + 1e-3;
            let n = rng.below(5);
            let cands: Vec<(String, f64)> = (0..n)
                .map(|i| c(&format!("v{i}"), rng.next_f64() * 20.0 + 1e-4))
                .collect();
            let alpha = 0.5 + rng.next_f64() * 0.5; // (0.5, 1.0]
            let t = chosen_time(&cands, t_b, alpha);
            assert!(
                t <= t_b + 1e-12,
                "regression: chosen {t} > baseline {t_b} (alpha {alpha})"
            );
        }
    }

    #[test]
    fn alpha_above_one_can_regress_hence_config_forbids_it() {
        // Documented edge: alpha > 1 breaks Prop 1; Config::validate
        // rejects it. Show the counterexample here.
        let cands = [c("v", 1.05)];
        let t = chosen_time(&cands, 1.0, 1.1);
        assert!(t > 1.0);
    }

    #[test]
    fn ties_resolved_to_first_minimum() {
        let cands = [c("a", 0.5), c("b", 0.5)];
        assert_eq!(decide(&cands, 1.0, 0.95).variant(), "a");
    }
}
