//! Roofline-style candidate estimate (paper §4.2: "shortlist candidates
//! with a roofline-style estimate").
//!
//! The estimate does not need to be accurate in absolute terms — it only
//! ranks candidates so the micro-probe times just the top-k. It charges
//! each variant for the bytes it must move on *this* bucket, so ELL
//! padding waste (the TPU analog of warp load imbalance) and hub-split
//! savings show up directly.

use crate::runtime::manifest::ArtifactEntry;

use super::features::InputFeatures;

/// Typed rejection of degenerate scheduling inputs (0 rows, 0 nnz,
/// F = 0). Without the gate these produce NaN / divide-by-zero roofline
/// terms and an unprobeable empty subgraph; the scheduler fails fast
/// with one of these instead.
#[derive(Debug, Clone, PartialEq)]
pub enum EstimateError {
    /// The graph has no rows or no stored edges.
    EmptyGraph { n_rows: usize, nnz: usize },
    /// The op consumes dense features but F = 0.
    ZeroFeatureDim,
    /// The device model has a non-positive bandwidth or peak rate.
    DegenerateDevice { mem_bw_gbps: f64, peak_gflops: f64 },
}

impl std::fmt::Display for EstimateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EstimateError::EmptyGraph { n_rows, nnz } => write!(
                f,
                "degenerate scheduling input: {n_rows} rows / {nnz} stored \
                 edges (both must be nonzero)"
            ),
            EstimateError::ZeroFeatureDim => {
                write!(f, "degenerate scheduling input: feature width F = 0")
            }
            EstimateError::DegenerateDevice { mem_bw_gbps, peak_gflops } => {
                write!(
                    f,
                    "degenerate device model: bw {mem_bw_gbps} GB/s, peak \
                     {peak_gflops} GFLOP/s (both must be positive)"
                )
            }
        }
    }
}

impl std::error::Error for EstimateError {}

/// Gate the roofline inputs. `requires_f` is `Op::has_f()` — softmax
/// legitimately schedules at F = 0.
pub fn validate_input(
    feats: &InputFeatures,
    requires_f: bool,
    dev: &DeviceModel,
) -> Result<(), EstimateError> {
    if feats.n_rows == 0 || feats.nnz == 0 {
        return Err(EstimateError::EmptyGraph {
            n_rows: feats.n_rows,
            nnz: feats.nnz,
        });
    }
    if requires_f && feats.f == 0 {
        return Err(EstimateError::ZeroFeatureDim);
    }
    let bad = |v: f64| !v.is_finite() || v <= 0.0;
    if bad(dev.mem_bw_gbps) || bad(dev.peak_gflops) {
        return Err(EstimateError::DegenerateDevice {
            mem_bw_gbps: dev.mem_bw_gbps,
            peak_gflops: dev.peak_gflops,
        });
    }
    Ok(())
}

/// Modeled traffic/compute for one candidate on one input.
#[derive(Debug, Clone, PartialEq)]
pub struct Estimate {
    pub entry_name: String,
    pub variant: String,
    pub bytes: f64,
    pub flops: f64,
    /// Roofline score: max(bytes / BW, flops / peak); lower is better.
    pub score: f64,
}

/// Device roofline constants. Absolute values only set the balance point
/// between bytes and flops; ranking is insensitive to modest error. The
/// defaults model one CPU core with SIMD (this testbed); `calibrate`
/// can overwrite them from two measured kernels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceModel {
    pub mem_bw_gbps: f64,
    pub peak_gflops: f64,
    /// Fixed cost per Pallas grid step on this backend. Interpret-mode
    /// grids run as XLA while-loops with per-step block slice/update
    /// copies — the CPU analog of CUDA kernel-launch/occupancy overhead,
    /// and the reason small-`r` row tiles lose here. A real TPU model
    /// would set this near zero and re-weight VMEM streaming instead.
    pub step_us: f64,
    /// Whether grid kernels pay the interpret-mode full-panel re-slice
    /// per step (PJRT CPU testbed). Native tiled kernels instead pay one
    /// extra read of the slot arrays per feature pass — far cheaper, and
    /// modeled separately in `estimate_entry`. Backends supply this via
    /// `Backend::device_model()`.
    pub grid_panel_emulation: bool,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            mem_bw_gbps: 8.0,
            peak_gflops: 8.0,
            step_us: 50.0,
            grid_panel_emulation: true,
        }
    }
}

const B4: f64 = 4.0; // bytes per f32 / i32

/// Model bytes/flops for an entry given input features.
/// Returns None for entries whose variant this model does not cover.
pub fn estimate_entry(
    entry: &ArtifactEntry,
    feats: &InputFeatures,
    dev: &DeviceModel,
) -> Option<Estimate> {
    let f = feats.f as f64;
    let n_pad = entry.param_usize("n_pad")? as f64;
    let v = entry.variant.as_str();
    // Pallas grid-step count (0 for grid-free gather variants and the
    // vendor baselines).
    let mut steps = 0.0;
    let mut panel_bytes = 0.0;
    if let (Some(r), Some(ft)) = (entry.param_usize("r"), entry.param_usize("ft")) {
        steps = (n_pad / r as f64) * (f / ft as f64).max(1.0);
        if dev.grid_panel_emulation {
            // Interpret-mode grids re-slice the (n_pad, ft) B/X/Y panel
            // every step (the emulation of the HBM→VMEM stream), so the
            // panel traffic scales with steps × n_pad — the term that
            // makes small-r row tiles non-viable at full size on the
            // PJRT CPU backend.
            panel_bytes = steps * n_pad * ft as f64 * B4;
        } else {
            // Native tiled kernels re-read the slot arrays (colind+val,
            // 8 bytes/slot over the row width) once per feature pass.
            // Hub-split kernels only feature-tile the LIGHT partition
            // (the hub block runs full-F once), so charge w_light there,
            // not the plain ELL width.
            let passes = (f / ft as f64).max(1.0) - 1.0;
            let w = entry
                .param_usize("w_light")
                .or(entry.param_usize("w"))
                .unwrap_or(1) as f64;
            panel_bytes = passes * n_pad * w * 2.0 * B4;
        }
    }
    let (bytes, flops) = match entry.op.as_str() {
        "spmm" => match v {
            // COO scatter: nnz-proportional, skew-immune. Scatter-add is
            // read-modify-write on C (factor 2) plus gathered B rows.
            "baseline_scatter" => {
                let nnz_pad = entry.param_usize("nnz_pad")? as f64;
                let bytes = nnz_pad * (3.0 * B4)          // row/col/val
                    + nnz_pad * f * B4                    // gather B rows
                    + 2.0 * nnz_pad * f * B4              // scatter-add C
                    + n_pad * f * B4;                     // C init
                (bytes, 2.0 * nnz_pad * f)
            }
            // Whole-row gather (grid-free): same slot traffic as the
            // row-tile kernel, no step overhead.
            "ell_gather" => {
                let w = entry.param_usize("w")? as f64;
                let slots = n_pad * w;
                let bytes = slots * (2.0 * B4)
                    + slots * f * B4
                    + 2.0 * n_pad * f * B4;
                (bytes, 2.0 * slots * f)
            }
            "hub_gather" => {
                let w_l = entry.param_usize("w_light")? as f64;
                let h_pad = entry.param_usize("h_pad")? as f64;
                let w_h = entry.param_usize("w_hub")? as f64;
                let slots = n_pad * w_l + h_pad * w_h;
                let bytes = slots * (2.0 * B4)
                    + slots * f * B4
                    + 2.0 * n_pad * f * B4
                    + 2.0 * h_pad * f * B4;
                (bytes, 2.0 * slots * f)
            }
            // Plain ELL row-tile: pays for every padded slot.
            _ if v.starts_with("ell_") => {
                let w = entry.param_usize("w")? as f64;
                let slots = n_pad * w;
                let bytes = slots * (2.0 * B4)            // colind + val
                    + slots * f * B4                      // gathered B rows
                    + 2.0 * n_pad * f * B4;               // B panel + C
                (bytes, 2.0 * slots * f)
            }
            // Hub split: light slots + hub slots + hub scatter.
            _ if v.starts_with("hub_") => {
                let w_l = entry.param_usize("w_light")? as f64;
                let h_pad = entry.param_usize("h_pad")? as f64;
                let w_h = entry.param_usize("w_hub")? as f64;
                let slots = n_pad * w_l + h_pad * w_h;
                let bytes = slots * (2.0 * B4)
                    + slots * f * B4
                    + 2.0 * n_pad * f * B4
                    + 2.0 * h_pad * f * B4;               // hub scatter-add
                (bytes, 2.0 * slots * f)
            }
            _ => return None,
        },
        "sddmm" => {
            // Gather-dot and the ELL kernel move the same data; they
            // differ in fusion/launch behaviour, which only the probe
            // can see — the estimate ranks them equal on purpose.
            if v != "baseline_gather" && !v.starts_with("ell_") {
                return None;
            }
            let w = entry.param_usize("w")? as f64;
            let slots = n_pad * w;
            let bytes = slots * (3.0 * B4)                // colind, mask, out
                + slots * f * B4                          // gathered Y rows
                + 2.0 * n_pad * f * B4;                   // X + Y panels
            (bytes, 2.0 * slots * f)
        }
        "softmax" => {
            let w = entry.param_usize("w")? as f64;
            let slots = n_pad * w;
            (slots * 3.0 * B4, 4.0 * slots)
        }
        "attention" => {
            let w = entry.param_usize("w")? as f64;
            let slots = n_pad * w;
            // SDDMM + softmax + SpMM over the same pattern.
            let bytes = slots * (8.0 * B4) + 2.0 * slots * f * B4
                + 4.0 * n_pad * f * B4;
            (bytes, 4.0 * slots * f + 4.0 * slots)
        }
        _ => return None,
    };
    let bytes = bytes + panel_bytes;
    let score = (bytes / (dev.mem_bw_gbps * 1e9))
        .max(flops / (dev.peak_gflops * 1e9))
        + steps * dev.step_us * 1e-6;
    // Belt-and-braces behind `validate_input`: a non-finite score would
    // poison the sort in `shortlist` (partial_cmp unwrap) downstream.
    if !score.is_finite() {
        return None;
    }
    Some(Estimate {
        entry_name: entry.name.clone(),
        variant: entry.variant.clone(),
        bytes,
        flops,
        score,
    })
}

/// Rank candidates by roofline score (ascending), applying feasibility
/// gates: wide-lane variants require alignment (vec gating) + the
/// `allow_vec` toggle; all variants must fit their bucket.
pub fn shortlist<'a>(
    entries: &[&'a ArtifactEntry],
    feats: &InputFeatures,
    dev: &DeviceModel,
    allow_vec: bool,
    top_k: usize,
) -> Vec<(&'a ArtifactEntry, Estimate)> {
    let mut scored: Vec<(&ArtifactEntry, Estimate)> = entries
        .iter()
        .filter(|e| {
            // The wide-lane ("vec4") gate: F % 128 == 0.
            if e.variant.contains("_f128") && !(feats.vec_aligned && allow_vec) {
                return false;
            }
            true
        })
        .filter_map(|e| estimate_entry(e, feats, dev).map(|est| (*e, est)))
        .collect();
    scored.sort_by(|a, b| a.1.score.partial_cmp(&b.1.score).unwrap());
    scored.truncate(top_k);
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::Path;

    fn fake_manifest() -> Manifest {
        Manifest::parse(
            Path::new("/x"),
            r#"{"entries":[
          {"name":"base","op":"spmm","variant":"baseline_scatter",
           "params":{"n_pad":4096,"w":512,"f":64,"nnz_pad":32768},
           "path":"a","inputs":[{"name":"row","dtype":"s32","shape":[32768]}]},
          {"name":"ell32","op":"spmm","variant":"ell_r8_f32",
           "params":{"n_pad":4096,"w":512,"f":64,"r":8,"ft":32},
           "path":"a","inputs":[{"name":"colind","dtype":"s32","shape":[4096,512]}]},
          {"name":"ellv","op":"spmm","variant":"ell_r8_f128",
           "params":{"n_pad":4096,"w":512,"f":64,"r":8,"ft":128},
           "path":"a","inputs":[{"name":"colind","dtype":"s32","shape":[4096,512]}]},
          {"name":"hub","op":"spmm","variant":"hub_r8_f32",
           "params":{"n_pad":4096,"w":512,"f":64,"r":8,"ft":32,
                     "w_light":8,"h_pad":1024,"w_hub":512},
           "path":"a","inputs":[{"name":"hub_rows","dtype":"s32","shape":[1024]}]}
        ]}"#,
        )
        .unwrap()
    }

    fn skewed_feats() -> InputFeatures {
        InputFeatures {
            n_rows: 4096,
            nnz: 330_000,
            f: 64,
            avg_deg: 80.0,
            p50_deg: 4.0,
            p90_deg: 512.0,
            p99_deg: 512.0,
            max_deg: 512,
            gini: 0.8,
            cv: 2.0,
            vec_aligned: false,
            tile_fill: 0.25,
            band_frac: 0.4,
        }
    }

    #[test]
    fn hub_split_beats_plain_ell_under_skew() {
        // Plain ELL at w=512 pays ~16x padding on a skewed graph vs the
        // hub split's (n*8 + 1024*512) slots — the estimate must rank
        // the split strictly better.
        let m = fake_manifest();
        let feats = skewed_feats();
        let dev = DeviceModel::default();
        let ell = estimate_entry(m.by_name("ell32").unwrap(), &feats, &dev).unwrap();
        let hub = estimate_entry(m.by_name("hub").unwrap(), &feats, &dev).unwrap();
        assert!(hub.score < ell.score);
    }

    #[test]
    fn scatter_baseline_scales_with_nnz_not_padding() {
        let m = fake_manifest();
        let feats = skewed_feats();
        let dev = DeviceModel::default();
        let base = estimate_entry(m.by_name("base").unwrap(), &feats, &dev).unwrap();
        let ell = estimate_entry(m.by_name("ell32").unwrap(), &feats, &dev).unwrap();
        assert!(base.score < ell.score); // 32k nnz vs 2M padded slots
    }

    #[test]
    fn vec_gate_blocks_unaligned() {
        let m = fake_manifest();
        let entries: Vec<&ArtifactEntry> = m.entries.iter().collect();
        let feats = skewed_feats(); // f=64 -> not vec aligned
        let dev = DeviceModel::default();
        let top = shortlist(&entries, &feats, &dev, true, 10);
        assert!(top.iter().all(|(e, _)| !e.variant.contains("_f128")));

        let mut aligned = feats.clone();
        aligned.f = 128;
        aligned.vec_aligned = true;
        let top = shortlist(&entries, &aligned, &dev, true, 10);
        assert!(top.iter().any(|(e, _)| e.variant.contains("_f128")));
        // AUTOSAGE_VEC=0 disables even when aligned.
        let top = shortlist(&entries, &aligned, &dev, false, 10);
        assert!(top.iter().all(|(e, _)| !e.variant.contains("_f128")));
    }

    #[test]
    fn validate_rejects_degenerate_inputs_typed() {
        let dev = DeviceModel::default();
        let ok = skewed_feats();
        assert!(validate_input(&ok, true, &dev).is_ok());
        assert!(validate_input(&ok, false, &dev).is_ok());

        let mut empty = skewed_feats();
        empty.n_rows = 0;
        assert_eq!(
            validate_input(&empty, true, &dev),
            Err(EstimateError::EmptyGraph { n_rows: 0, nnz: empty.nnz })
        );
        let mut no_edges = skewed_feats();
        no_edges.nnz = 0;
        assert!(matches!(
            validate_input(&no_edges, true, &dev),
            Err(EstimateError::EmptyGraph { .. })
        ));

        let mut f0 = skewed_feats();
        f0.f = 0;
        assert_eq!(
            validate_input(&f0, true, &dev),
            Err(EstimateError::ZeroFeatureDim)
        );
        // Softmax-style ops (no F parameter) accept F = 0.
        assert!(validate_input(&f0, false, &dev).is_ok());

        let dead = DeviceModel { mem_bw_gbps: 0.0, ..DeviceModel::default() };
        assert!(matches!(
            validate_input(&ok, true, &dead),
            Err(EstimateError::DegenerateDevice { .. })
        ));
        // Errors render actionable messages.
        let msg = format!("{}", EstimateError::ZeroFeatureDim);
        assert!(msg.contains("F = 0"), "{msg}");
    }

    #[test]
    fn non_finite_scores_are_dropped_not_sorted() {
        // A zero-bandwidth device would make every score infinite; the
        // entry estimator must drop such candidates instead of handing
        // `shortlist` a NaN/inf to sort on.
        let m = fake_manifest();
        let dev = DeviceModel {
            mem_bw_gbps: 0.0,
            peak_gflops: 0.0,
            ..DeviceModel::default()
        };
        assert_eq!(
            estimate_entry(m.by_name("ell32").unwrap(), &skewed_feats(), &dev),
            None
        );
    }

    #[test]
    fn shortlist_truncates_and_sorts() {
        let m = fake_manifest();
        let entries: Vec<&ArtifactEntry> = m.entries.iter().collect();
        let top = shortlist(&entries, &skewed_feats(), &DeviceModel::default(), true, 2);
        assert_eq!(top.len(), 2);
        assert!(top[0].1.score <= top[1].1.score);
    }
}
