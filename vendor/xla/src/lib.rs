//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT C API and executes compiled HLO. This
//! stub exists so `--features pjrt` *compiles* in environments without
//! the PJRT runtime: every entry point returns [`XlaError`] at runtime.
//! To actually execute AOT artifacts, point the `xla` path dependency in
//! the workspace `Cargo.toml` at the real bindings — the API surface
//! below matches what `runtime::client::Device` uses.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' displayable error.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: PJRT runtime unavailable (offline `xla` stub; swap \
         vendor/xla for the real bindings to execute artifacts)"
    )))
}

pub struct PjRtClient(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct Literal(());
pub struct HloModuleProto(());
pub struct XlaComputation(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
    pub fn platform_version(&self) -> String {
        "0.0".to_string()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _bufs: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
    pub fn copy_raw_to_host_sync(&self, _dst: &mut [f32], _offset: usize) -> Result<()> {
        unavailable("PjRtBuffer::copy_raw_to_host_sync")
    }
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
