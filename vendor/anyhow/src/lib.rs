//! Offline stand-in for the `anyhow` crate.
//!
//! This environment has no crates.io access, so the subset of the anyhow
//! API the workspace uses is re-implemented here behind the same names:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! [`anyhow!`] / [`bail!`] macros. Error values carry a plain string
//! context chain (innermost cause first); `{:#}` formatting prints the
//! whole chain outermost-first, mirroring anyhow's alternate Display.

use std::fmt::{self, Debug, Display};

/// `Result` with a defaulted error type, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error value, optionally carrying the typed root-cause
/// payload (set by [`Error::new`]) so callers can `downcast_ref` it.
pub struct Error {
    /// Context chain, innermost (root cause) first.
    chain: Vec<String>,
    /// The typed root cause, when built via [`Error::new`]. Context
    /// wrapping preserves it; string construction leaves it `None`.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Build an error from a typed std error, keeping the value so
    /// [`Error::downcast_ref`] can recover it (as in real anyhow).
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { chain: vec![e.to_string()], payload: Some(Box::new(e)) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    /// The typed root cause, if this error was built via [`Error::new`]
    /// with a value of type `T` (context wrapping does not erase it).
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // "{:#}": outermost context first, `: `-separated chain.
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

// Like anyhow: any std error converts into `Error` (this is what makes
// `?` work on io::Error etc.); `Error` itself deliberately does NOT
// implement std::error::Error so this blanket impl stays coherent with
// the reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, as in anyhow.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("writing cache").unwrap_err();
        assert_eq!(format!("{e:#}"), "writing cache: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| "missing flag").unwrap_err();
        assert_eq!(format!("{e}"), "missing flag");
        assert_eq!(Some(1).context("x").unwrap(), 1);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "disk on fire");
    }

    #[test]
    fn typed_errors_survive_context_and_downcast() {
        let e = Error::new(io_err()).context("saving");
        assert_eq!(format!("{e:#}"), "saving: disk on fire");
        let io = e.downcast_ref::<std::io::Error>().expect("payload kept");
        assert_eq!(io.to_string(), "disk on fire");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // String-built errors have no typed payload.
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
        // `?`-converted std errors are downcastable too.
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn macros_format_and_bail() {
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(format!("{e}"), "x = 7");
        let e = anyhow!("pair {} {}", 1, 2);
        assert_eq!(format!("{e}"), "pair 1 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
        fn f() -> Result<()> {
            bail!("no {}", "good");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "no good");
    }
}
